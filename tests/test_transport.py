"""Mixed-precision expert transport: codec round-trip properties,
packed-byte accounting exactness, store bytes-moved pinning, per-link
timing scaling, the re-pinned Eq. (1) I/O boundary, and the tentpole
invariant — every decode path (engine, composed serving, fleet faults)
token-bit-identical to ``greedy_generate`` *under the same transport
policy*.

Property tests run through tests/_hypothesis_shim.py (zero-arg
signatures — no pytest fixtures; module-level lazy state instead).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from _timing_ref import effective_gbps, expected_t_load, link_t_load
from conftest import tiny_moe
from repro.configs import get_config
from repro.core import (ExpertStore, GroupSchedule, DecodeClock,
                        ODMoEEngine, RTX3090_EDGE, WorkerSlots,
                        simulate_odmoe, synthetic_trace)
from repro.fleet import FaultEvent, FaultInjector, FleetSchedule, \
    WorkerProfile
from repro.models import greedy_generate, init_params
from repro.quant import (SCHEMES, TieredPolicy, TransportCodec,
                         UniformPolicy, resolve_policy,
                         transport_expert_bytes)
from repro.quant.quantize import (NF4_BLOCK, dequantize_nf4, quantize_int8,
                                  quantize_nf4)
from repro.serve import Request, ServingLoop

CFG = tiny_moe(num_layers=4)
N_TOK = 6

# module-level lazy state (shim property tests cannot take fixtures)
_MODEL = None


def _model():
    global _MODEL
    if _MODEL is None:
        params = init_params(CFG, jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (1, 12), 0, CFG.vocab_size)}
        _MODEL = (params, batch)
    return _MODEL


# ------------------------------------------------------ codec properties
@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 10**6), rows=st.integers(1, 48),
       cols=st.integers(1, 48))
def test_int8_scale_bounded_by_absmax(seed, rows, cols):
    """Per-channel int8 scale is <= absmax/127 (+ the 1e-8 floor), and
    the round trip errs by at most half a step per channel."""
    w = np.random.default_rng(seed).standard_normal(
        (rows, cols)).astype(np.float32)
    q, scale = quantize_int8(jnp.asarray(w))
    absmax = np.abs(w).max(axis=0)
    s = np.asarray(scale).reshape(-1)
    assert np.all(s <= np.maximum(absmax, 1e-8) / 127.0 + 1e-12)
    back = np.asarray(q).astype(np.float32) * np.asarray(scale)
    assert np.all(np.abs(back - w) <= s[None, :] * 0.5 + 1e-6)


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 10**6), n=st.integers(1, 400))
def test_nf4_blockwise_never_amplifies(seed, n):
    """NF4 levels live in [-1, 1], so a dequantized block's absmax can
    never exceed the block's original absmax scale."""
    w = np.random.default_rng(seed).standard_normal(n).astype(np.float32)
    codes, scales = quantize_nf4(jnp.asarray(w))
    back = np.asarray(dequantize_nf4(codes, scales, (n,)))
    padded = np.pad(w, (0, (-n) % NF4_BLOCK)).reshape(-1, NF4_BLOCK)
    absmax = np.maximum(np.abs(padded).max(axis=1), 1e-8)
    back_blocks = np.pad(back, (0, (-n) % NF4_BLOCK)).reshape(-1, NF4_BLOCK)
    assert np.all(np.abs(back_blocks).max(axis=1) <= absmax + 1e-6)


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 10**6), rows=st.integers(1, 80),
       cols=st.integers(1, 80), scheme=st.sampled_from(SCHEMES))
def test_packed_bytes_accounting_exact(seed, rows, cols, scheme):
    """The closed-form ``packed_nbytes`` equals the actual packed
    payload byte-for-byte, for every codec and shape — what lets the
    timing model price full-size configs no store ever materializes."""
    w = np.random.default_rng(seed).standard_normal(
        (rows, cols)).astype(np.float32)
    codec = TransportCodec(scheme)
    pw = codec.pack(w)
    assert pw.nbytes == codec.packed_nbytes(w.shape, elem_bytes=4)
    back = np.asarray(codec.round_trip(w))
    assert back.shape == w.shape and back.dtype == w.dtype
    if scheme == "fp32":                     # identity wire format
        assert np.array_equal(back, w)


def test_transport_expert_bytes_matches_store():
    """Analytic per-expert bytes == the store's actual packed shards,
    for every scheme on a real model."""
    params, _ = _model()
    for scheme in SCHEMES:
        store = ExpertStore(CFG, params, policy=scheme)
        li = store.moe_layers[0]
        assert store.packed_bytes(li, 0) == \
            transport_expert_bytes(CFG, scheme, weight_bytes=4), scheme


# ----------------------------------------------- store: bytes-moved pinning
def test_bytes_moved_pinned():
    """Scripted load/hit/evict/fail sequence under int8 transport —
    packed shards are cached once at construction, only physical loads
    move bytes, and they move exactly the packed payload (mirrors
    test_fleet.py::test_stats_accounting_pinned)."""
    params, _ = _model()
    store = ExpertStore(CFG, params, policy="int8")
    li = store.moe_layers[0]
    # packed once at construction: repeated gets return the same shard
    assert store.get_packed(li, 0) is store.get_packed(li, 0)
    b = store.packed_bytes(li, 0)
    # ~25% codes + per-channel scale overhead (the <=26% acceptance
    # bound is pinned on Mixtral shapes in the frontier test below)
    assert 0 < b <= 0.27 * store.expert_bytes
    s = WorkerSlots(store, 4, physical=False)
    s.load(0, li, 0, worker=0, predicted=True)      # moves b
    s.load(0, li, 0, worker=0, predicted=True)      # hit: moves nothing
    s.load(0, li, 1, worker=1, predicted=False)     # moves b
    s.evict(0)                                      # moves nothing
    s.fail(1)                                       # moves nothing
    s.load(0, li, 2, worker=2, predicted=False)     # moves b
    assert s.bytes_moved == 3 * b
    assert [(e.scheme, e.bytes) for e in s.events] == [("int8", b)] * 3
    # fp32 store: event bytes equal the classic full payload
    store32 = ExpertStore(CFG, params)
    s32 = WorkerSlots(store32, 2, physical=False)
    s32.load(0, li, 0, worker=0, predicted=True)
    assert s32.bytes_moved == store32.expert_bytes
    assert s32.events[0].scheme == "fp32"


def test_device_bytes_counts_transient_packed():
    """Peak worker memory during dequantize-on-arrival holds BOTH the
    in-flight packed buffer and the full-width slot — pinned against
    ``ExpertStore.packed_bytes`` per scheme.  fp32 transport aliases the
    arriving buffer (no double-buffering), so it keeps the historical
    slots-only value."""
    params, _ = _model()
    for scheme in SCHEMES:
        store = ExpertStore(CFG, params, policy=scheme)
        slots = WorkerSlots(store, 4, physical=False)
        packed_max = max(
            store.packed_bytes(li, e) for li in store.moe_layers
            for e in range(CFG.num_experts))
        if scheme == "fp32":
            assert slots.transient_packed_bytes() == 0
            assert slots.device_bytes_per_worker() == store.expert_bytes
        else:
            assert slots.transient_packed_bytes() == packed_max
            assert slots.device_bytes_per_worker() == \
                store.expert_bytes + packed_max, scheme
    # a mixed policy's peak transient counts only sub-fp32 arrivals
    moe_layers = [i for i, (_, ff) in enumerate(CFG.layer_kinds())
                  if ff == "moe"]
    tiered = TieredPolicy({(li, 0) for li in moe_layers},
                          high="fp32", low="int8")
    store_t = ExpertStore(CFG, params, policy=tiered)
    slots_t = WorkerSlots(store_t, 4, physical=False)
    li0 = store_t.moe_layers[0]
    assert slots_t.transient_packed_bytes() == store_t.packed_bytes(li0, 0)


# --------------------------------------------------------- timing scaling
def test_t_load_scales_exactly_with_packed_bytes():
    """Per-link t_load under a codec == fp32 t_load x packed-byte
    ratio, on healthy, heterogeneous and throttled links alike."""
    full = get_config("mixtral-8x7b")
    profs = tuple(WorkerProfile(w, link_gbps=(48.0 if w == 1 else None))
                  for w in range(8))
    sched = FleetSchedule(8, 2, profiles=profs)
    sched.state.throttle(2, 0.25)
    try:
        base = DecodeClock(full, sched, RTX3090_EDGE)
        fp32_bytes = transport_expert_bytes(full, "fp32")
        for scheme in ("fp16", "int8", "nf4"):
            clock = DecodeClock(full, sched, RTX3090_EDGE,
                                transport=scheme)
            ratio = transport_expert_bytes(full, scheme) / fp32_bytes
            for w in range(8):
                assert clock.t_load_for(w) == pytest.approx(
                    base.t_load_for(w) * ratio, rel=1e-12), (scheme, w)
                # and absolutely: packed bytes over effective bandwidth
                assert clock.t_load_for(w) == pytest.approx(
                    expected_t_load(
                        full, sched, w, scheme,
                        default_gbps=RTX3090_EDGE.pcie_gbps),
                    rel=1e-12), (scheme, w)
    finally:
        sched.state.reset()
    # base (non-fleet) schedules price the same way
    g = GroupSchedule(8, 2)
    c32 = DecodeClock(full, g, RTX3090_EDGE)
    c8 = DecodeClock(full, g, RTX3090_EDGE, transport="int8")
    ratio = transport_expert_bytes(full, "int8") / fp32_bytes
    assert c8.t_load == pytest.approx(c32.t_load * ratio, rel=1e-12)


def test_io_boundary_repinned_for_int8():
    """Eq. (1) per-link: a link I/O-bound shipping fp32 experts becomes
    compute-bound shipping int8 — and the boundary stays strict (exact
    budget hidden, one ulp more stalls)."""
    full = get_config("mixtral-8x7b")
    sched = FleetSchedule(8, 2)
    b32 = transport_expert_bytes(full, "fp32")
    b8 = transport_expert_bytes(full, "int8")
    # pick a budget between the int8 and fp32 load times on the default
    # link: fp32 blows it, int8 hides under it
    gbps = effective_gbps(sched, 0)
    t8, t32 = link_t_load(b8, gbps), link_t_load(b32, gbps)
    tm = (t8 + t32) / 2 / 4          # t_maxload = 4*tm + 3*tw, tw=0
    assert sched.io_bottlenecked_worker(0, b32, tm, 0.0)
    assert not sched.io_bottlenecked_worker(0, b8, tm, 0.0)
    # strictness at the exact boundary, in bytes
    budget_bytes = sched.t_maxload(tm, 0.0) * (gbps * 1e9)
    assert not sched.io_bottlenecked_worker(0, budget_bytes, tm, 0.0)
    assert sched.io_bottlenecked_worker(
        0, np.nextafter(budget_bytes, np.inf), tm, 0.0)


def test_modeled_tpot_frontier_mixtral():
    """Acceptance pin: on the Mixtral config, int8 transport's modeled
    TPOT is strictly below fp32, per-expert packed bytes <= 26% of
    fp32, and TPOT is monotone non-increasing as payload shrinks."""
    full = get_config("mixtral-8x7b")
    tr = synthetic_trace(full, 32, recall=0.97)
    sched = GroupSchedule(8, 2)
    tpot, frac = {}, {}
    fp32_bytes = transport_expert_bytes(full, "fp32")
    for s in SCHEMES:
        t = simulate_odmoe(full, tr, sched, RTX3090_EDGE, transport=s)
        tpot[s] = float(np.mean(t.per_token_s))
        frac[s] = transport_expert_bytes(full, s) / fp32_bytes
    assert tpot["int8"] < tpot["fp32"]
    assert frac["int8"] <= 0.26
    order = sorted(SCHEMES, key=lambda s: -frac[s])   # fp32 ... nf4
    for heavier, lighter in zip(order, order[1:]):
        assert tpot[lighter] <= tpot[heavier] * (1 + 1e-9)


# --------------------------------------------------- the tentpole invariant
@pytest.mark.parametrize("scheme", [
    "int8",
    pytest.param("fp16", marks=pytest.mark.slow),
    pytest.param("nf4", marks=pytest.mark.slow)])
def test_engine_bitexact_under_transport(scheme):
    """Engine decode with quantized expert transport == the dense
    reference under the SAME policy, with strictly fewer wire bytes."""
    params, batch = _model()
    policy = UniformPolicy(scheme)
    ref = np.asarray(greedy_generate(CFG, params, batch, N_TOK,
                                     transport=policy))
    eng = ODMoEEngine(CFG, params, n_workers=8, predictor="sep",
                      shadow_scheme="int8", transport=policy)
    toks, _ = eng.generate(batch, N_TOK)
    assert np.array_equal(np.asarray(toks), ref), scheme
    assert eng.slots.bytes_moved < \
        eng.slots.stats["loads"] * eng.store.expert_bytes
    assert all(e.scheme == scheme for e in eng.slots.events)


def test_tiered_policy_calibrates_and_stays_bitexact():
    """HOBBIT-style tiering from a calibration trace: both tiers are
    populated, and decode stays bit-identical to the reference under
    the same tiered policy."""
    params, batch = _model()
    cal = ODMoEEngine(CFG, params, n_workers=8, predictor="freq")
    _, cal_trace = cal.generate(batch, N_TOK)
    assert cal_trace.records[0].layers[0].gates is not None
    policy = TieredPolicy.from_trace(cal_trace, low_fraction=0.5,
                                     num_experts=CFG.num_experts)
    assert policy.low_experts                       # low tier non-empty
    schemes = {policy.scheme_for(li, e)
               for li in cal.moe_layers for e in range(CFG.num_experts)}
    assert schemes == {"fp16", "int8"}              # both tiers in use
    ref = np.asarray(greedy_generate(CFG, params, batch, N_TOK,
                                     transport=policy))
    eng = ODMoEEngine(CFG, params, n_workers=8, predictor="freq",
                      transport=policy)
    toks, _ = eng.generate(batch, N_TOK)
    assert np.array_equal(np.asarray(toks), ref)
    # mixed schemes actually crossed the wire
    assert {e.scheme for e in eng.slots.events} == {"fp16", "int8"}


def test_tiered_unseen_experts_ship_low():
    """The from_trace contract: low-gate seen experts and ALL experts
    the calibration never routed to (up to the config's num_experts)
    ship at the low tier."""
    from repro.core import LayerRecord, TokenRecord, Trace
    tr = Trace()
    rec = TokenRecord(index=1, aligned_token=True, aligned_kv=True)
    rec.layers.append(LayerRecord(
        layer=0, moe_index=0, group=0, predicted=None,
        true=np.array([[0, 1]]), correct=0, reloads=0, assignments=[],
        gates=np.array([[0.9, 0.1]])))
    tr.records.append(rec)
    pol = TieredPolicy.from_trace(tr, low_fraction=0.5, num_experts=8)
    assert pol.scheme_for(0, 0) == "fp16"    # highest confidence seen
    assert pol.scheme_for(0, 1) == "int8"    # bottom half of seen
    assert all(pol.scheme_for(0, e) == "int8" for e in range(2, 8))


def test_resolve_policy_forms():
    assert resolve_policy(None).trivial
    assert resolve_policy("fp32").trivial
    assert resolve_policy("int8").scheme_for(0, 0) == "int8"
    p = UniformPolicy("nf4")
    assert resolve_policy(p) is p
    with pytest.raises(ValueError):
        UniformPolicy("int4")
    with pytest.raises(TypeError):
        resolve_policy(42)


@pytest.mark.slow
def test_serving_composed_transport_bitexact():
    """Composed continuous-batching decode under int8 transport: every
    request == its solo reference under the same policy, and step
    durations price loads by packed bytes (faster than fp32 serving of
    the identical traffic)."""
    params, _ = _model()
    policy = UniformPolicy("int8")
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab_size,
                                        int(rng.integers(5, 10))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(3, 6)),
                    arrival_s=a)
            for i, a in enumerate([0.0, 0.0, 0.01])]
    durs = {}
    for pol in (policy, None):
        eng = ODMoEEngine(CFG, params, n_workers=8, predictor="sep",
                          shadow_scheme="int8", transport=pol)
        res = ServingLoop(eng, max_batch=3).run(reqs)
        durs[pol] = sum(s.duration_s for s in res.steps)
        for r in reqs:
            solo = np.asarray(greedy_generate(
                CFG, params, {"tokens": jnp.asarray(r.prompt)[None, :]},
                r.max_new_tokens, transport=pol))[0]
            assert np.array_equal(solo, res.outputs[r.rid]), (r.rid, pol)
    assert durs[policy] < durs[None]     # codec savings reach serving TPOT


@pytest.mark.slow
def test_fleet_chaos_transport_bitexact():
    """A worker killed mid-step strands its quantized predicted load;
    the reload lands on a survivor and tokens stay bit-identical to the
    reference under the same transport policy."""
    params, batch = _model()
    policy = UniformPolicy("int8")
    ref = np.asarray(greedy_generate(CFG, params, batch, N_TOK,
                                     transport=policy))
    kill = FaultEvent(step=3, worker=1, kind="kill", moe_index=0)
    eng = ODMoEEngine(CFG, params, n_workers=8, predictor="sep",
                      shadow_scheme="fp16", faults=FaultInjector([kill]),
                      transport=policy)
    toks, trace = eng.generate(batch, N_TOK)
    assert np.array_equal(np.asarray(toks), ref)
    assert not eng.slots.alive[1]
    # degraded replay still prices loads at packed bytes
    t = simulate_odmoe(CFG, trace, FleetSchedule(8, 2), RTX3090_EDGE,
                       shadow_scheme="fp16",
                       faults=FaultInjector([kill]), transport=policy)
    assert t.degraded_report(8)["degraded_steps"] > 0
