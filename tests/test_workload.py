"""Trace-driven workload generation and the SLO-aware serving stack:
heavy-tailed length samplers, bursty/diurnal arrival processes, tenant
classes, percentile + per-tenant reporting, deadline-slack preemption,
fair composition, priority admission, and the chunked-prefill clock
reconciliation.  The scheduling-policy tests pin the repo's core
invariant from the policy side: every policy combination must emit
token streams bit-identical to FIFO serving and to solo decoding."""
import math

import jax
import numpy as np
import pytest

from conftest import tiny_moe
from repro.core import (RTX3090_EDGE, ServingTimings, latency_percentiles,
                        poisson_arrivals, simulate_prefill_odmoe)
from repro.models import init_params
from repro.serve import (BatchComposer, DEFAULT_TENANTS, KVPool, Request,
                         RequestState, ServingLoop, TenantClass,
                         WorkloadSpec, bursty_arrivals, diurnal_arrivals,
                         heavy_tail_lengths, make_trace,
                         preemption_victim)

slow = pytest.mark.slow

CFG = tiny_moe(num_layers=4)


@pytest.fixture(scope="module")
def model():
    return CFG, init_params(CFG, jax.random.PRNGKey(0))


def interarrival_cv(arrivals):
    gaps = np.diff(arrivals)
    return float(np.std(gaps) / np.mean(gaps))


# ------------------------------------------------------- length samplers
def test_heavy_tail_lognormal_shape():
    rng = np.random.default_rng(0)
    xs = heavy_tail_lengths(rng, 4000, median=32, dist="lognormal")
    assert xs.dtype.kind == "i" and xs.min() >= 2
    med = float(np.median(xs))
    assert 24 <= med <= 40                    # anchored at the median
    # heavy tail: p99 several multiples of the median
    assert np.percentile(xs, 99) > 2.5 * med


def test_heavy_tail_zipf_clamped():
    rng = np.random.default_rng(1)
    xs = heavy_tail_lengths(rng, 4000, median=16, dist="zipf",
                            lo=4, hi=256)
    assert xs.min() >= 4 and xs.max() <= 256
    assert xs.max() > 8 * np.median(xs)       # power-law tail fires


def test_heavy_tail_seeded():
    a = heavy_tail_lengths(np.random.default_rng(7), 64, median=16)
    b = heavy_tail_lengths(np.random.default_rng(7), 64, median=16)
    c = heavy_tail_lengths(np.random.default_rng(8), 64, median=16)
    assert np.array_equal(a, b) and not np.array_equal(a, c)


# ----------------------------------------------------- arrival processes
@pytest.mark.parametrize("gen", [bursty_arrivals, diurnal_arrivals],
                         ids=["bursty", "diurnal"])
def test_arrivals_sorted_nonnegative_seeded(gen):
    a = gen(50.0, 400, seed=3)
    assert len(a) == 400
    assert a[0] >= 0.0 and np.all(np.diff(a) >= 0)
    assert np.array_equal(a, gen(50.0, 400, seed=3))
    assert not np.array_equal(a, gen(50.0, 400, seed=4))


def test_bursty_is_burstier_than_poisson():
    """Cluster arrivals push interarrival CV well past the Poisson
    baseline of ~1 — the property that makes the trace stress admission
    and preemption instead of trickling in."""
    cv_p = interarrival_cv(poisson_arrivals(50.0, 3000, seed=0))
    cv_b = interarrival_cv(bursty_arrivals(50.0, 3000, seed=0))
    assert 0.8 <= cv_p <= 1.2
    assert cv_b > 1.5 * cv_p


def test_diurnal_rate_varies_across_cycle():
    """Thinning against a sinusoidal rate: the busiest window holds
    substantially more arrivals than the quietest window of equal
    width."""
    a = diurnal_arrivals(40.0, 2000, seed=0, depth=0.8)
    hist, _ = np.histogram(a, bins=16)
    assert hist.max() > 2 * max(hist.min(), 1)


# ---------------------------------------------------------- trace making
def test_make_trace_seeded_and_tagged():
    spec = WorkloadSpec(n_requests=48, rate=100.0)
    a = make_trace(CFG, spec, seed=5)
    b = make_trace(CFG, spec, seed=5)
    assert [r.rid for r in a] == list(range(48))
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    assert [r.arrival_s for r in a] == [r.arrival_s for r in b]
    assert [r.tenant for r in a] == [r.tenant for r in b]
    arr = [r.arrival_s for r in a]
    assert arr == sorted(arr)
    names = {t.name for t in DEFAULT_TENANTS}
    for r in a:
        assert r.tenant in names and r.weight > 0
        assert 1 <= len(r.prompt) <= spec.max_prompt
        assert 1 <= r.max_new_tokens <= spec.max_output
    # interactive (share 3) should dominate batch (share 1)
    n_int = sum(r.tenant == "interactive" for r in a)
    assert n_int > len(a) // 2


def test_make_trace_respects_tenant_slos():
    spec = WorkloadSpec(n_requests=32, rate=100.0)
    by_name = {t.name: t for t in DEFAULT_TENANTS}
    for r in make_trace(CFG, spec, seed=0):
        t = by_name[r.tenant]
        assert r.ttft_slo_s == t.ttft_slo_s
        assert r.tpot_slo_s == t.tpot_slo_s
        assert r.weight == t.weight


def test_workload_spec_validation():
    with pytest.raises(ValueError):
        WorkloadSpec(n_requests=-1)
    with pytest.raises(ValueError):
        WorkloadSpec(arrival="weibull")
    with pytest.raises(ValueError):
        WorkloadSpec(length_dist="cauchy")
    with pytest.raises(ValueError):
        TenantClass("t", share=0.0)
    with pytest.raises(ValueError):
        Request(rid=0, prompt=np.zeros(3, np.int32), max_new_tokens=2,
                weight=0.0)


# --------------------------------------------- percentile / SLO reporting
def test_latency_percentiles_ordering_and_empty():
    rng = np.random.default_rng(0)
    p = latency_percentiles(list(rng.lognormal(0, 1, 500)), "ttft")
    assert p["ttft_p50_s"] <= p["ttft_p95_s"] <= p["ttft_p99_s"]
    empty = latency_percentiles([], "tpot")
    assert set(empty) == {"tpot_mean_s", "tpot_p50_s", "tpot_p95_s",
                          "tpot_p99_s"}
    assert all(v == 0.0 for v in empty.values())


def test_empty_timings_report_all_finite():
    """The empty-run regression: no requests must mean zeros, never a
    numpy ValueError (percentile of []) or inf (tokens / 0 makespan)."""
    t = ServingTimings([], [], [], [])
    assert t.makespan_s == 0.0
    rep = t.report()
    assert t.tokens_per_s == 0.0 and rep["throughput_tok_s"] == 0.0
    for k, v in rep.items():
        if isinstance(v, float):
            assert math.isfinite(v), f"{k}={v}"
    per = t.per_tenant_report()
    assert list(per) == ["default"] and per["default"]["n_requests"] == 0


def test_zero_makespan_tokens_per_s_finite():
    t = ServingTimings(arrival_s=[0.0], first_token_s=[0.0],
                       finish_s=[0.0], tokens=[1])
    assert t.tokens_per_s == 0.0
    assert t.report()["throughput_tok_s"] == 0.0


def test_single_request_report_and_attainment():
    t = ServingTimings(arrival_s=[0.0], first_token_s=[0.5],
                       finish_s=[2.0], tokens=[16],
                       tenants=["interactive"], ttft_slo_s=[1.0],
                       tpot_slo_s=[0.05])
    assert t.tpot_s == [pytest.approx(0.1)]
    rep = t.report()
    assert rep["ttft_p50_s"] == rep["ttft_p99_s"] == 0.5
    assert rep["ttft_slo_attainment"] == 1.0   # 0.5 <= 1.0
    assert rep["tpot_slo_attainment"] == 0.0   # 0.1 > 0.05
    per = t.per_tenant_report()
    assert per["interactive"]["n_requests"] == 1
    assert per["interactive"]["tpot_slo_attainment"] == 0.0


def test_per_tenant_report_splits_classes():
    t = ServingTimings(arrival_s=[0.0, 0.0, 0.0],
                       first_token_s=[0.1, 0.9, 0.2],
                       finish_s=[0.13, 0.96, 0.23], tokens=[4, 4, 4],
                       tenants=["a", "b", "a"],
                       ttft_slo_s=[0.5, 0.5, 0.5],
                       tpot_slo_s=[math.inf] * 3)
    per = t.per_tenant_report()
    assert per["a"]["n_requests"] == 2 and per["b"]["n_requests"] == 1
    assert per["a"]["ttft_slo_attainment"] == 1.0
    assert per["b"]["ttft_slo_attainment"] == 0.0
    # infinite SLO = vacuous attainment, reported finite
    assert per["a"]["tpot_slo_attainment"] == 1.0


# ------------------------------------------------- scheduling policy units
def fake_state(rid, admit_seq, *, tenant="default", weight=1.0,
               tpot_slo=math.inf, first_token_s=0.0, n_generated=0,
               prefilling=False):
    r = Request(rid=rid, prompt=np.zeros(4, np.int32), max_new_tokens=8,
                tenant=tenant, weight=weight, tpot_slo_s=tpot_slo)
    s = RequestState(request=r, token=None, cache_list=[], pos=None,
                     prefilling=prefilling,
                     first_token_s=first_token_s)
    s.generated = [0] * n_generated
    s.admit_seq = admit_seq
    return s


def test_preemption_victim_youngest_default():
    states = [fake_state(0, 0), fake_state(1, 1), fake_state(2, 2)]
    assert preemption_victim(states, "youngest", now=1.0).rid == 2


def test_preemption_victim_slack_reduces_to_youngest_untagged():
    """No TPOT SLOs -> every slack is infinite -> the admit_seq
    tie-break makes ``slack`` identical to ``youngest``.  This is what
    keeps the pre-existing preemption pins valid under the new
    policy."""
    states = [fake_state(0, 0), fake_state(1, 1), fake_state(2, 2)]
    assert preemption_victim(states, "slack", now=5.0).rid == 2


def test_preemption_victim_slack_spares_tight_deadline():
    # rid 0: old but tight SLO (slack 0.1*3 - 0.2 = 0.1s); rid 2:
    # young best-effort (infinite slack) -> slack victimizes rid 2,
    # youngest would also pick rid 2; flip ages to separate policies
    tight_young = fake_state(0, 5, tpot_slo=0.1, first_token_s=0.0,
                             n_generated=3)
    loose_old = fake_state(1, 0)
    assert preemption_victim([tight_young, loose_old], "slack",
                             now=0.2).rid == 1
    assert preemption_victim([tight_young, loose_old], "youngest",
                             now=0.2).rid == 0


def test_preemption_victim_slack_skips_prefilling_slo():
    s = fake_state(0, 0, tpot_slo=0.1, prefilling=True)
    assert s.deadline_slack(100.0) == math.inf


def test_fair_composer_weighted_shares():
    """Deficit round-robin: across many compositions, a weight-4 tenant
    earns ~4x the non-seed seats of a weight-1 tenant, and the weight-1
    tenant is never starved."""
    comp = BatchComposer(max_batch=3, policy="fair")
    seats = {"hi": 0, "lo": 0}
    # seed (admission head) is a neutral third tenant so the measured
    # seats are pure policy choices
    pool = ([fake_state(0, 0)]
            + [fake_state(10 + i, 10 + i, tenant="hi", weight=4.0)
               for i in range(4)]
            + [fake_state(20 + i, 20 + i, tenant="lo", weight=1.0)
               for i in range(4)])
    for _ in range(50):
        chosen = comp.compose(pool)
        assert chosen[0].rid == 0            # head-of-line always rides
        assert [s.rid for s in chosen] == sorted(s.rid for s in chosen)
        for s in chosen[1:]:
            seats[s.request.tenant] += 1
    assert seats["lo"] > 0
    assert 2.5 <= seats["hi"] / seats["lo"] <= 6.0


def test_fair_composer_single_tenant_is_fifo_like():
    comp = BatchComposer(max_batch=3, policy="fair")
    pool = [fake_state(i, i) for i in range(6)]
    assert [s.rid for s in comp.compose(pool)] == [0, 1, 2]


# ------------------------------------------- chunked prefill cost slicing
def test_chunk_cost_slices_reconcile_exactly():
    """The satellite-3 pin, arithmetic form: the loop slices the ONE
    full-prompt ``simulate_prefill_odmoe`` cost across chunks with an
    exact float remainder, so the chunked clock total equals the
    unchunked cost bit-for-bit.  Per-chunk simulation calls (the old
    charging) do NOT reconcile — prefill cost is not additive in
    prompt length."""
    n, c = 23, 4
    chunks = [c] * (n // c) + ([n % c] if n % c else [])
    t_full = simulate_prefill_odmoe(CFG, RTX3090_EDGE, n, n_workers=8)
    costs = [t_full * ch / n for ch in chunks]
    costs[-1] = t_full - sum(costs[:-1])
    assert sum(costs) == t_full              # exact, not approx
    assert all(t > 0 for t in costs)
    old_style = sum(simulate_prefill_odmoe(CFG, RTX3090_EDGE, ch,
                                           n_workers=8) for ch in chunks)
    assert not np.isclose(old_style, t_full, rtol=1e-3)


@slow
def test_chunked_prefill_clock_matches_unchunked(model):
    """Same single request, chunked vs unchunked admission: identical
    tokens AND identical modeled first-token time — chunking shapes
    when the cost lands, never how much it is."""
    cfg, params = model
    rng = np.random.default_rng(2)

    def one_run(chunk):
        req = Request(rid=0,
                      prompt=rng.integers(0, cfg.vocab_size, 17
                                          ).astype(np.int32),
                      max_new_tokens=4)
        from repro.core import ODMoEEngine
        eng = ODMoEEngine(cfg, params, n_workers=8, predictor="sep",
                          shadow_scheme="fp16")
        loop = ServingLoop(eng, max_batch=2, prefill_chunk=chunk)
        res = loop.run([req])
        eng.close()
        return res.outputs[0], res.timings.ttft_s[0]

    rng = np.random.default_rng(2)
    out_chunked, ttft_chunked = one_run(5)
    rng = np.random.default_rng(2)
    out_plain, ttft_plain = one_run(0)
    assert np.array_equal(out_chunked, out_plain)
    assert ttft_chunked == pytest.approx(ttft_plain, rel=1e-9)


# ------------------------------------------------ end-to-end bit-exactness
@slow
def test_scheduled_stack_bitexact_vs_fifo(model):
    """The whole SLO-aware stack (priority admission + slack preemption
    + fair composition over a constrained KV pool) against plain FIFO
    serving of the same trace: per-request token streams must be
    IDENTICAL.  Scheduling moves time, never tokens."""
    import jax.numpy as jnp

    from repro.core import ODMoEEngine
    from repro.models import greedy_generate

    cfg, params = model
    spec = WorkloadSpec(n_requests=5, rate=200.0, arrival="bursty",
                        prompt_median=8, min_prompt=4, max_prompt=12,
                        output_median=3, max_output=5)
    reqs = make_trace(cfg, spec, seed=1)
    cache_len = max(len(r.prompt) + r.max_new_tokens for r in reqs) + 2

    def serve(scheduled):
        eng = ODMoEEngine(cfg, params, n_workers=8, predictor="sep",
                          shadow_scheme="fp16")
        if scheduled:
            pages = -(-cache_len // 4)
            pool = KVPool(cfg, num_pages=int(pages * len(reqs) * 0.6),
                          page_tokens=4)
            loop = ServingLoop(
                eng, max_batch=3, kv_pool=pool,
                composer=BatchComposer(3, "fair", kv_pool=pool),
                preempt="slack", admit="priority")
        else:
            loop = ServingLoop(eng, max_batch=3)
        res = loop.run([Request(r.rid, r.prompt, r.max_new_tokens,
                                r.arrival_s, r.tenant, r.weight,
                                r.ttft_slo_s, r.tpot_slo_s)
                        for r in reqs])
        eng.close()
        return res

    res_sched = serve(True)
    res_fifo = serve(False)
    for r in reqs:
        ref = np.asarray(greedy_generate(
            cfg, params, {"tokens": jnp.asarray(r.prompt)[None, :]},
            r.max_new_tokens))[0]
        assert np.array_equal(res_sched.outputs[r.rid], ref)
        assert np.array_equal(res_sched.outputs[r.rid],
                              res_fifo.outputs[r.rid])
    rep = res_sched.timings.report()
    assert rep["ttft_p50_s"] <= rep["ttft_p95_s"] <= rep["ttft_p99_s"]
    per = res_sched.tenant_report()
    assert sum(v["n_requests"] for v in per.values()) == len(reqs)
    for tr in per.values():
        for k, v in tr.items():
            if isinstance(v, float):
                assert math.isfinite(v), f"{k}={v}"
