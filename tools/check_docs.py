"""Docs coverage check: every top-level package under ``src/repro``
must be mentioned (as ``repro.<pkg>``, ``src/repro/<pkg>`` or
``<pkg>/``) in README.md or a file under docs/.

Run directly (CI) or via tests/test_docs.py (tier-1):

    python tools/check_docs.py
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def repro_packages() -> list:
    base = os.path.join(ROOT, "src", "repro")
    # namespace packages (e.g. launch/) have no __init__.py — any
    # directory holding python modules counts
    return sorted(
        d for d in os.listdir(base)
        if os.path.isdir(os.path.join(base, d))
        and any(f.endswith(".py")
                for f in os.listdir(os.path.join(base, d))))


def doc_text() -> str:
    texts = []
    readme = os.path.join(ROOT, "README.md")
    if os.path.exists(readme):
        with open(readme) as f:
            texts.append(f.read())
    docs_dir = os.path.join(ROOT, "docs")
    if os.path.isdir(docs_dir):
        for name in sorted(os.listdir(docs_dir)):
            if name.endswith(".md"):
                with open(os.path.join(docs_dir, name)) as f:
                    texts.append(f.read())
    return "\n".join(texts)


def missing_packages() -> list:
    text = doc_text()
    missing = []
    for pkg in repro_packages():
        pattern = (rf"repro[./]{pkg}\b|src/repro/{pkg}\b|`{pkg}/`"
                   rf"|\b{pkg}/ ")
        if not re.search(pattern, text):
            missing.append(pkg)
    return missing


def main() -> int:
    pkgs = repro_packages()
    if not pkgs:
        print("no packages found under src/repro — wrong checkout?")
        return 1
    missing = missing_packages()
    if missing:
        print("packages not mentioned in README.md or docs/:")
        for pkg in missing:
            print(f"  src/repro/{pkg}")
        return 1
    print(f"docs cover all {len(pkgs)} src/repro packages")
    return 0


if __name__ == "__main__":
    sys.exit(main())
